"""Fig. 16: group-size sweep (resource vs scheduling time) and factor-weight
sensitivity (equal vs tuned weights); §5.6 similarity-vs-optimal grouping."""
from __future__ import annotations

import numpy as np

from repro.core import GraftPlanner, plan_optimal

from benchmarks.common import Rows, book, timed
from benchmarks.bench_merging import _frag_population


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    model = "inc"
    frags = _frag_population(model, b, n=25, seed=5)
    for gs in ([3, 5] if quick else [2, 3, 5, 7, 10]):
        with timed() as tb:
            plan = GraftPlanner(b, group_size=gs).plan(frags)
        rows.add(f"grouping/fig16a/{model}/gs_{gs}", tb["us"],
                 f"resource={plan.total_resource:.0f}")
    # factor weights: equal vs a small tuned sweep
    combos = [(1, 1, 1), (1, 2, 1), (2, 1, 1), (1, 1, 2)]
    best = None
    equal_res = None
    for w in combos:
        with timed() as tb:
            plan = GraftPlanner(b, group_weights=w).plan(frags)
        if w == (1, 1, 1):
            equal_res = plan.total_resource
        if best is None or plan.total_resource < best[1]:
            best = (w, plan.total_resource)
    gap = 100 * (equal_res - best[1]) / best[1] if best[1] else 0.0
    rows.add(f"grouping/fig16b/{model}/equal_vs_best", 0.0,
             f"equal={equal_res:.0f};best={best[1]:.0f};"
             f"best_w={best[0]};gap_pct={gap:.1f}")
    # §5.6: similarity grouping vs optimal grouping (small instance)
    small = _frag_population(model, b, n=8, seed=6)
    with timed() as tg:
        g = GraftPlanner(b, merge_strategy="none").plan(small)
    with timed() as to:
        o = plan_optimal(small, b)
    gap = 100 * (g.total_resource - o.total_resource) / o.total_resource \
        if o.total_resource else 0.0
    rows.add("grouping/similarity_vs_optimal", tg["us"],
             f"graft={g.total_resource:.0f};optimal={o.total_resource:.0f};"
             f"gap_pct={gap:.1f};optimal_us={to['us']:.0f}")

"""Shared benchmark scaffolding.

Every bench emits rows ``(name, us_per_call, derived)`` where us_per_call is
the scheduler/simulator wall time per invocation and ``derived`` carries the
paper's metric for that table/figure (resource %, violation rate, ...).
"""
from __future__ import annotations

import time
from contextlib import contextmanager

import numpy as np

from repro.core import default_book
from repro.serving import make_fleet, fleet_fragments

_BOOK = None

PAPER_MODELS = ("inc", "res", "vgg", "mob", "vit")


def book():
    global _BOOK
    if _BOOK is None:
        _BOOK = default_book()
    return _BOOK


def rate_for(model: str) -> float:
    return 1.0 if model == "vit" else 30.0       # §5.1: ViT at 1 RPS


def scenario(model: str, scale: str, seed: int = 0, t: float = 42.0):
    """Paper testbeds -> (fleet, fragments)."""
    b = book()
    n = {"small": (4, 0), "small_het": (4, 2),
         "large": (20, 0), "large_het": (15, 5)}[scale]
    fleet = make_fleet(model, b, n_nano=n[0], n_tx2=n[1],
                       rate=rate_for(model), seed=seed)
    return fleet, fleet_fragments(fleet, b, t=t)


class Rows:
    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")


@contextmanager
def timed():
    t0 = time.perf_counter()
    box = {}
    yield box
    box["us"] = (time.perf_counter() - t0) * 1e6

"""Transport data-path costs: serialization, per-hop latency, and the
warm-vs-cold replan wall time the ROADMAP asks for.

Three sections:

  * ``serialize/*`` — encode+decode round trip of activation-sized
    frames (the cost every hop pays, socket or not).
  * ``hop/*`` — request/reply round trip through InProcessTransport
    (framing only) vs SocketTransport (framing + localhost TCP), same
    payload, persistent connection.
  * ``replan/*`` — a live executor transitions to a plan with one new
    pool (``warm``: surviving pools keep their jitted programs / worker
    processes) vs tearing the deployment down and redeploying from
    scratch (``cold``: every pool recompiles). Both flavours run even in
    quick mode — the subprocess one is the only honest cold number
    (in-process recompiles hit jax's shared compilation cache) and the
    CI gate's baseline carries its metrics — so cold pays worker spawn +
    jax import per pool.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows, timed


def _bench_serialize(rows: Rows, quick: bool) -> None:
    from repro.serving.transport import decode_frame, encode_frame
    shapes = [(16, 256)] if quick else [(16, 256), (64, 1024), (256, 1024)]
    rng = np.random.RandomState(0)
    for shape in shapes:
        payload = rng.randn(*shape).astype(np.float32)
        msg = {"op": "submit", "req_id": 1, "client": "c0",
               "payload": payload, "extras": None}
        reps = 50 if quick else 200
        encode_frame(msg)                                   # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = decode_frame(encode_frame(msg))
        us = (time.perf_counter() - t0) / reps * 1e6
        assert np.array_equal(out["payload"], payload)
        nbytes = payload.nbytes
        rows.add(f"transport/serialize/{shape[0]}x{shape[1]}", us,
                 f"payload_bytes={nbytes};"
                 f"mbytes_per_s={nbytes / (us / 1e6) / 1e6:.0f}")


def _bench_hop(rows: Rows, quick: bool) -> None:
    from repro.serving.transport import InProcessTransport, SocketTransport
    rng = np.random.RandomState(1)
    payload = rng.randn(16, 256).astype(np.float32)
    reps = 100 if quick else 500
    for name, tp in (("inprocess", InProcessTransport()),
                     ("socket", SocketTransport())):
        with tp:
            tp.serve("echo", lambda m: {"ok": True, "payload": m["payload"]})
            ch = tp.connect("echo")
            ch.request({"op": "echo", "payload": payload})   # warm
            t0 = time.perf_counter()
            for _ in range(reps):
                ch.request({"op": "echo", "payload": payload})
            us = (time.perf_counter() - t0) / reps * 1e6
            ch.close()
        rows.add(f"transport/hop/{name}", us,
                 f"payload_bytes={payload.nbytes};"
                 f"round_trips={reps}")


def _bench_replan(rows: Rows, quick: bool) -> None:
    from repro.core import GraftPlanner
    from repro.core.fragment import Fragment
    from repro.serving import GraftExecutor, InProcessTransport
    from repro.serving.smoke import smoke_requests, smoke_setup

    cfg, book, params = smoke_setup()
    planner = GraftPlanner(book)
    frags1 = [Fragment(cfg.name, 0, 60.0, 30.0, client="c0"),
              Fragment(cfg.name, 0, 55.0, 30.0, client="c1")]
    frags2 = frags1 + [Fragment(cfg.name, 1, 70.0, 30.0, client="c2")]
    plan1, plan2 = planner.plan(frags1), planner.plan(frags2)

    def flavours():
        # in-process: measures the framing/data-path half only — repeat
        # compiles of an identical fragment hit jax's in-process
        # compilation cache, so warm ~= cold here by construction
        yield "inprocess", GraftExecutor, InProcessTransport
        # subprocess workers: the honest cold number (process spawn + jax
        # import + fragment compile per pool) vs warm pools kept alive —
        # the wall-time version of the ROADMAP's "keep warm instances"
        from repro.serving import SocketTransport
        from repro.serving.remote import RemoteExecutor
        yield "socket", RemoteExecutor, SocketTransport

    for name, cls, make_tp in flavours():
        # live deployment on plan1, fully compiled
        ex = cls(plan1, params, cfg, transport=make_tp())
        ex.serve(smoke_requests(cfg, frags1, seed=2))
        with timed() as warm:
            ex.apply_plan(plan2)                 # only the new pool compiles
            ex.serve(smoke_requests(cfg, frags2, seed=3))
        kept = ex.stats["pools_reused"]
        ex.close()
        # scratch: a fresh deployment of plan2 compiles every pool
        with timed() as cold:
            ex2 = cls(plan2, params, cfg, transport=make_tp())
            ex2.serve(smoke_requests(cfg, frags2, seed=3))
        ex2.close()
        warm_ms, cold_ms = warm["us"] / 1e3, cold["us"] / 1e3
        rows.add(f"transport/replan/{name}/warm", warm["us"],
                 f"warm_ms={warm_ms:.1f};pools_kept={kept}")
        rows.add(f"transport/replan/{name}/cold", cold["us"],
                 f"cold_ms={cold_ms:.1f}")
        rows.add(f"transport/replan/{name}/delta", 0.0,
                 f"cold_vs_warm={cold_ms / max(warm_ms, 1e-9):.1f}x")


def run(rows: Rows, *, quick=False) -> None:
    _bench_serialize(rows, quick)
    _bench_hop(rows, quick)
    _bench_replan(rows, quick)

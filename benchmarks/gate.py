"""CI bench gate: run the quick benches, snapshot to BENCH_ci.json, and
fail on regressions against a checked-in baseline.

  python -m benchmarks.gate --baseline benchmarks/baseline.json --out BENCH_ci.json
  python -m benchmarks.gate --write-baseline     # refresh the baseline

Gated metrics (relative, 20% band by default — wall-clock benches on
shared runners are noisy, so only the two the ISSUE calls load-bearing
are *blocking*):

  * ``planner_latency_us``   — incremental planner time per replan
                               (``incremental/<model>`` us_per_call);
                               fails when slower than baseline * (1+tol).
  * ``slo_attainment``       — controller-mode attainment from the
                               online-serving bench; fails when below
                               baseline * (1-tol).

  * ``server_p99_ms``        — event-driven serving-runtime tail latency
                               from ``benchmarks/bench_server.py``'s
                               paced phase; BLOCKING since the baseline
                               gained the key (PR 4) — ``scripts/ci.sh``
                               runs this gate in the default (blocking)
                               job.
  * ``telemetry_overhead_frac`` — throughput-mode makespan inflation
                               with telemetry (registry + full span
                               sampling) on vs off; gated on an
                               ABSOLUTE 5% ceiling, no baseline needed.
  * ``ttft_ms`` / ``tpot_ms`` / ``kv_block_util_frac`` — decode serving
                               (``benchmarks/bench_decode.py``,
                               continuous-batching phase): first-token
                               and per-token wall clock gate like
                               server_p99_ms (wide band); arena
                               utilization gates on an absolute DROP
                               (lower = block accounting leak).
  * ``disagg_ttft_ms`` / ``kv_handoff_ms`` — disaggregated
                               prefill/decode phase
                               (``decode/serve/disagg`` row): TTFT over
                               the two-phase admit and the cross-pool
                               KV-block handoff cost, both on the same
                               wide (2.5x) wall-clock band.

Everything else (controller replan latency, transport hop/serialize,
warm-vs-cold replan wall times, server makespan ratio, fleet scale-out
ratio and overload shed numbers) is recorded in BENCH_ci.json for trend
inspection but not gated. A baseline metric missing from the current
run only fails the gate when it is one of the GATED keys above — so a
subset ``--only`` run (the blocking job skips the slow transport
benches) still gates what it measured.

Refreshing the baseline: rerun ``--write-baseline`` on a quiet machine
at the commit you want to bless, eyeball the diff of
``benchmarks/baseline.json``, and check it in alongside the change that
legitimately moved the numbers.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import sys

DEFAULT_ONLY = "incremental,controller,transport,server,kernels,decode,router"
DEFAULT_TOL = 0.20


def parse_derived(derived: str) -> dict:
    """'a=1;b=x2' -> {'a': 1.0, 'b': 'x2'} (floats where possible)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v.rstrip("x%"))
        except ValueError:
            out[k] = v
    return out


def run_benches(only: str, quick: bool = True) -> list:
    """Run benchmarks.run in-process; -> [(name, us, derived_str), ...]."""
    from benchmarks import run as bench_run
    buf = io.StringIO()
    argv = ["--only", only] + (["--quick"] if quick else [])
    with contextlib.redirect_stdout(buf):
        bench_run.main(argv)
    rows = []
    for line in buf.getvalue().splitlines():
        if not line or line.startswith("name,"):
            continue
        name, us, derived = line.split(",", 2)
        rows.append((name, float(us), derived))
    return rows


def extract_metrics(rows: list) -> dict:
    """The gated + headline numbers, keyed stably for baseline diffs."""
    metrics = {}
    for name, us, derived in rows:
        d = parse_derived(derived)
        if name.startswith("incremental/"):
            model = name.split("/", 1)[1]
            metrics[f"planner_latency_us/{model}"] = us
        elif name.endswith("/controller") and "slo_attainment" in d:
            model = name.split("/")[1]
            metrics[f"slo_attainment/{model}"] = d["slo_attainment"]
            metrics[f"controller_replan_us/{model}"] = us
        elif name.startswith("transport/replan/") and name.endswith("/warm"):
            metrics[f"replan_warm_ms/{name.split('/')[2]}"] = d["warm_ms"]
        elif name.startswith("transport/replan/") and name.endswith("/cold"):
            metrics[f"replan_cold_ms/{name.split('/')[2]}"] = d["cold_ms"]
        elif name.startswith("transport/hop/"):
            metrics[f"hop_us/{name.split('/')[2]}"] = us
        elif name == "server/latency":
            metrics["server_p99_ms"] = d["p99_ms"]
            metrics["server_p50_ms"] = d["p50_ms"]
        elif name == "server/makespan/pipelined":
            metrics["server_makespan_ratio"] = d["ratio"]
        elif name == "fleet/scaleout":
            metrics["fleet_scaleout_ratio"] = d["ratio"]
        elif name.startswith("fleet/overload/"):
            kind = name.split("/")[2]
            metrics[f"fleet_{kind}_p99_ms"] = d["p99_ms"]
            metrics[f"fleet_{kind}_attainment"] = d["attainment"]
        elif name.startswith("fleet/skew/") and name != "fleet/skew/win":
            # hot-client skew: the weighted arm is the gated headline
            # (router_skew_p99_ms), the HRW arm is recorded so the win
            # ratio can be recomputed from the snapshot
            kind = name.split("/")[2]
            prefix = "router_skew" if kind == "weighted" else "router_hrw"
            metrics[f"{prefix}_p99_ms"] = d["p99_ms"]
            metrics[f"{prefix}_attainment"] = d["attainment"]
            if kind == "weighted":
                metrics["router_skew_steals"] = d["steals"]
        elif name == "fleet/skew/win":
            metrics["router_skew_win_ratio"] = d["p99_ratio"]
        elif name == "fleet/remote/win":
            # per-front-end vs shared worker channels (recorded, not
            # gated: worker-subprocess wall clock on shared runners)
            metrics["fleet_remote_channel_ratio"] = d["p99_ratio"]
        elif name == "kernels/fragment/packed":
            # ragged fragment execution on the serving hot path: packed
            # wall clock per mixed-length round (micro-bench scale)
            metrics["fragment_exec_ms"] = d["fragment_exec_ms"]
        elif name == "server/packing/packed":
            # end-to-end packing efficiency of the serving runtime —
            # the two counters the ISSUE gates strictly below the
            # pad-to-bucket baseline row (recorded alongside)
            metrics["padding_waste_frac"] = d["padding_waste_frac"]
            metrics["recompile_count"] = d["recompile_count"]
        elif name == "server/packing/padded":
            metrics["padded_waste_frac"] = d["padding_waste_frac"]
            metrics["padded_recompile_count"] = d["recompile_count"]
        elif name == "decode/serve/continuous":
            # the decode serving headline: continuous-batching TTFT/TPOT
            # and paged-arena utilization — BLOCKING once baselined
            metrics["ttft_ms"] = d["ttft_ms"]
            metrics["tpot_ms"] = d["tpot_ms"]
            metrics["kv_block_util_frac"] = d["kv_block_util_frac"]
            metrics["decode_toks_s"] = d["toks_s"]
        elif name == "decode/serve/disagg":
            # disaggregated prefill/decode: TTFT stamped at the prefill
            # pool's first token, plus the cross-pool KV handoff cost
            # (admit wall time when a KV frame rides the hop) — both
            # BLOCKING once baselined, same wide band as ttft_ms
            metrics["disagg_ttft_ms"] = d["ttft_ms"]
            metrics["kv_handoff_ms"] = d["kv_handoff_ms"]
            metrics["disagg_toks_s"] = d["toks_s"]
        elif name == "decode/serve/waved":
            # close-on-flush baseline: recorded for the win ratio
            metrics["decode_waved_ttft_ms"] = d["ttft_ms"]
            metrics["decode_waved_toks_s"] = d["toks_s"]
        elif name == "server/telemetry":
            # observability cost: throughput-mode makespan inflation with
            # the registry live + every request span-sampled, vs telemetry
            # off over the same warm executor
            metrics["telemetry_overhead_frac"] = d["telemetry_overhead_frac"]
            metrics["telemetry_makespan_ms"] = d["makespan_on_ms"]
        elif name == "decode/prefix/reuse":
            metrics["decode_prefix_tokens_reused"] = \
                d["prefix_tokens_reused"]
    return metrics


GATED_PREFIXES = ("planner_latency_us/", "slo_attainment/")
GATED_KEYS = ("server_p99_ms", "fragment_exec_ms", "padding_waste_frac",
              "recompile_count", "ttft_ms", "tpot_ms",
              "kv_block_util_frac", "telemetry_overhead_frac",
              "router_skew_p99_ms", "disagg_ttft_ms", "kv_handoff_ms")

# the observability layer's standing claim: leaving the registry +
# tracing on may not inflate paced mean latency by more than this —
# an ABSOLUTE ceiling, checked even before the baseline carries the key
TELEMETRY_OVERHEAD_MAX = 0.05


def _gated(key: str) -> bool:
    return key in GATED_KEYS or key.startswith(GATED_PREFIXES)


def compare(metrics: dict, baseline: dict, tol: float) -> list:
    """-> list of failure strings; empty means the gate passes."""
    failures = []
    frac = metrics.get("telemetry_overhead_frac")
    if frac is not None and frac > TELEMETRY_OVERHEAD_MAX:
        failures.append(
            f"telemetry_overhead_frac: {frac:.4f} "
            f"(> {TELEMETRY_OVERHEAD_MAX:.0%} absolute ceiling — "
            f"telemetry is no longer cheap enough to leave on)")
    for key, base in baseline.get("metrics", {}).items():
        cur = metrics.get(key)
        if cur is None:
            if _gated(key):
                failures.append(f"{key}: missing from current run "
                                f"(baseline {base:.4g})")
            continue
        if key.startswith("planner_latency_us/"):
            if cur > base * (1 + tol):
                failures.append(
                    f"{key}: {cur:.0f} us vs baseline {base:.0f} us "
                    f"(>{tol:.0%} slower)")
        elif key.startswith("slo_attainment/"):
            if cur < base * (1 - tol):
                failures.append(
                    f"{key}: {cur:.3f} vs baseline {base:.3f} "
                    f"(>{tol:.0%} worse)")
        elif key == "server_p99_ms":
            # serving-runtime tail latency: BLOCKING (baselined in PR 4).
            # Wall-clock tails on shared 2-core runners are far noisier
            # than planner CPU time, so this key gets 2.5x the band —
            # it still catches the step-function regressions (a lost
            # pipelining path, a compile on the hot path) it exists for.
            wide = 2.5 * tol
            if cur > base * (1 + wide):
                failures.append(
                    f"{key}: {cur:.2f} ms vs baseline {base:.2f} ms "
                    f"(>{wide:.0%} slower)")
        elif key == "fragment_exec_ms":
            # packed-round wall clock: micro-bench on shared runners —
            # same wide band as server_p99_ms, catches step functions
            # (packing silently off, per-depth recompiles back)
            wide = 2.5 * tol
            if cur > base * (1 + wide):
                failures.append(
                    f"{key}: {cur:.3f} ms vs baseline {base:.3f} ms "
                    f"(>{wide:.0%} slower)")
        elif key in ("ttft_ms", "tpot_ms", "disagg_ttft_ms",
                     "kv_handoff_ms"):
            # decode serving wall-clock tails (single-pool and
            # disaggregated) plus the cross-pool KV handoff cost: same
            # wide band as server_p99_ms — catches step functions
            # (continuous admission lost, a compile back on the step
            # loop, a serialize copy on the handoff), not
            # shared-runner jitter
            wide = 2.5 * tol
            if cur > base * (1 + wide):
                failures.append(
                    f"{key}: {cur:.2f} ms vs baseline {base:.2f} ms "
                    f"(>{wide:.0%} slower)")
        elif key == "router_skew_p99_ms":
            # hot-client skew p99 under the weighted router: wall-clock
            # tail on shared runners, so the same 2.5x band — catches
            # the router silently degrading to HRW (signals never fresh,
            # stealing dead), not scheduler jitter
            wide = 2.5 * tol
            if cur > base * (1 + wide):
                failures.append(
                    f"{key}: {cur:.2f} ms vs baseline {base:.2f} ms "
                    f"(>{wide:.0%} slower)")
        elif key == "telemetry_overhead_frac":
            # gated on the absolute ceiling above, not the baseline —
            # "5% slower than an already-5% overhead" is not a pass
            pass
        elif key == "kv_block_util_frac":
            # arena utilization is a fraction of deterministic traffic:
            # additive band, LOWER is worse (blocks held but empty —
            # a leak in free/retain accounting)
            if cur < base - 0.08:
                failures.append(
                    f"{key}: {cur:.4f} vs baseline {base:.4f} "
                    f"(> -0.08 absolute drop)")
        elif key == "padding_waste_frac":
            # a FRACTION of a deterministic traffic mix, not wall clock:
            # additive band. +0.05 absolute means the bucket policy or
            # the tail-pad accounting changed, not runner noise.
            if cur > base + 0.05:
                failures.append(
                    f"{key}: {cur:.4f} vs baseline {base:.4f} "
                    f"(> +0.05 absolute)")
        elif key == "recompile_count":
            # distinct traced shapes over a deterministic run: integer,
            # near-deterministic. Small slack (+2) for batch-close
            # timing races in the pipelined phase; anything above means
            # the compile-cache keying regressed.
            if cur > base * (1 + tol) + 2:
                failures.append(
                    f"{key}: {cur:.0f} compiles vs baseline {base:.0f} "
                    f"(> base*(1+{tol:.0%})+2)")
        # other metrics: recorded, not gated
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.gate")
    ap.add_argument("--only", default=DEFAULT_ONLY)
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--out", default="BENCH_ci.json")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOL)
    ap.add_argument("--full", action="store_true",
                    help="run the full (non --quick) benches")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the baseline file instead of gating")
    ap.add_argument("--retries", type=int, default=1,
                    help="re-run the benches up to N times when the gate "
                         "fails, taking the element-wise best (shared "
                         "runners throttle in bursts)")
    args = ap.parse_args(argv)

    rows = run_benches(args.only, quick=not args.full)
    metrics = extract_metrics(rows)
    snapshot = {
        "only": args.only,
        "quick": not args.full,
        "metrics": metrics,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }

    if args.write_baseline:
        with open(args.baseline, "w") as f:
            json.dump({"only": args.only, "quick": not args.full,
                       "metrics": metrics}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline written to {args.baseline} "
              f"({len(metrics)} metrics)")
        return 0

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        with open(args.out, "w") as f:
            json.dump(snapshot, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"no baseline at {args.baseline}; gate skipped "
              f"(run --write-baseline to create one)", file=sys.stderr)
        return 0

    # retry on failure: shared runners throttle in bursts, so one bad
    # interval must not fail the gate when a clean re-run shows the code
    # is fine. The retry must pass ON ITS OWN — runs are never merged
    # element-wise (that could pass on a metrics vector no run produced)
    failures = compare(metrics, baseline, args.tolerance)
    for attempt in range(args.retries):
        if not failures:
            break
        print(f"gate failed (attempt {attempt + 1}); re-running benches "
              f"to rule out a throttling burst:", file=sys.stderr)
        for fmsg in failures:
            print(f"  - {fmsg}", file=sys.stderr)
        rows = run_benches(args.only, quick=not args.full)
        metrics = extract_metrics(rows)
        snapshot["metrics"] = metrics
        snapshot["rows"] = [{"name": n, "us_per_call": us, "derived": d}
                            for n, us, d in rows]
        snapshot["retried"] = attempt + 1
        failures = compare(metrics, baseline, args.tolerance)

    with open(args.out, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench snapshot written to {args.out} ({len(rows)} rows)")
    for key in ("planner_latency_us", "slo_attainment",
                "replan_warm_ms", "replan_cold_ms"):
        vals = {k.split("/", 1)[1]: v for k, v in metrics.items()
                if k.startswith(key + "/")}
        if vals:
            print(f"  {key}: " + "  ".join(
                f"{m}={v:.4g}" for m, v in sorted(vals.items())))
    srv = {k: v for k, v in metrics.items() if k.startswith("server_")}
    if srv:
        print("  server: " + "  ".join(
            f"{k[7:]}={v:.4g}" for k, v in sorted(srv.items())))
    dec = {k: v for k, v in metrics.items()
           if k in ("ttft_ms", "tpot_ms", "kv_block_util_frac",
                    "decode_toks_s", "decode_waved_ttft_ms",
                    "disagg_ttft_ms", "kv_handoff_ms")}
    if dec:
        print("  decode: " + "  ".join(
            f"{k}={v:.4g}" for k, v in sorted(dec.items())))
    if failures:
        print("BENCH GATE FAILED:", file=sys.stderr)
        for fmsg in failures:
            print(f"  - {fmsg}", file=sys.stderr)
        return 1
    print(f"bench gate passed ({len(baseline.get('metrics', {}))} baseline "
          f"metrics, tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Kernel micro-benchmarks (CPU wall time of the jnp reference paths; the
Pallas kernels are TPU-target and validated in interpret mode, so wall time
here tracks the reference implementations the dry-run lowers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from benchmarks.common import Rows


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(rows: Rows, *, quick=False) -> None:
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = (1, 512, 4, 2, 64) if quick else (2, 1024, 8, 2, 64)
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, hd), jnp.float32)

    naive = jax.jit(lambda a, b, c: ref.ref_attention(a, b, c, causal=True))
    chunked = jax.jit(lambda a, b, c: ref.chunked_attention(
        a, b, c, causal=True, q_chunk=256))
    us_n = _time(naive, q, k, v)
    us_c = _time(chunked, q, k, v)
    flops = 4 * B * S * S * H * hd / 2
    toks = B * S                          # tokens attended per call
    rows.add("kernels/attn_naive", us_n,
             f"gflops_s={flops/us_n/1e3:.1f};"
             f"tokens_s={toks/us_n*1e6:.0f}")
    rows.add("kernels/attn_chunked", us_c,
             f"gflops_s={flops/us_c/1e3:.1f};"
             f"tokens_s={toks/us_c*1e6:.0f};vs_naive={us_n/us_c:.2f}x")

    T, Hh, hdd = (256, 2, 32) if quick else (1024, 4, 64)
    r = jax.random.normal(key, (B, T, Hh, hdd)) * 0.5
    kk = jax.random.normal(key, (B, T, Hh, hdd)) * 0.5
    vv = jax.random.normal(key, (B, T, Hh, hdd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(key, (B, T, Hh, hdd))) * 0.8 + 0.1
    u = jax.random.normal(key, (Hh, hdd)) * 0.1
    s0 = jnp.zeros((B, Hh, hdd, hdd), jnp.float32)
    f_scan = jax.jit(lambda *a: ref.ref_wkv6(*a))
    f_chnk = jax.jit(lambda *a: ref.chunked_wkv6(*a))
    us_s = _time(f_scan, r, kk, vv, w, u, s0)
    us_k = _time(f_chnk, r, kk, vv, w, u, s0)
    wkv_toks = B * T
    rows.add("kernels/wkv6_token_scan", us_s,
             f"impl=lax.scan_per_token;tokens_s={wkv_toks/us_s*1e6:.0f}")
    rows.add("kernels/wkv6_chunked", us_k,
             f"impl=matmul_chunks;tokens_s={wkv_toks/us_k*1e6:.0f};"
             f"vs_scan={us_s/us_k:.2f}x")

    run_fragment(rows, quick=quick)


def _length_mixes(rng, *, n_rounds: int, max_batch: int, lens) -> list:
    """Deterministic ragged traffic: per round, a batch of random sizes
    with lengths drawn from ``lens``."""
    return [[int(rng.choice(lens)) for _ in range(rng.randint(1, max_batch + 1))]
            for _ in range(n_rounds)]


def run_fragment(rows: Rows, *, quick=False) -> None:
    """Ragged fragment execution on the serving hot path: the packed
    (cu_seqlens) FragmentInstance vs the pad-to-bucket baseline over the
    SAME mixed-length traffic. Derives the gated keys
    ``fragment_exec_ms`` (packed wall clock per round),
    ``padding_waste_frac`` and ``recompile_count`` (both per variant —
    the gate tracks the packed ones)."""
    from repro import models as M
    from repro.configs import get_smoke_config
    from repro.core.plandiff import PoolSpec
    from repro.serving.executor import FragmentInstance, ServeRequest

    cfg = get_smoke_config("qwen3-1.7b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    L = M.n_fragment_units(cfg)
    max_batch = 4
    lens = (8, 12, 16, 24)
    n_rounds = 4 if quick else 12
    spec = PoolSpec(key=(cfg.name, 0, L), share=100, batch=max_batch,
                    n_instances=1)

    for packed in (False, True):
        rng = np.random.RandomState(7)        # identical traffic per variant
        mixes = _length_mixes(rng, n_rounds=n_rounds, max_batch=max_batch,
                              lens=lens)
        inst = FragmentInstance(params, cfg, spec, packed=packed)

        def round_(mix):
            for i, S in enumerate(mix):
                req = ServeRequest(client=f"c{i}", tokens=rng.randint(
                    0, cfg.vocab_size, S).astype(np.int32))
                inst.submit(req, jnp.asarray(req.tokens))
            for _, y in inst.flush():
                np.asarray(y)                 # block: count the full round

        t_warm0 = time.perf_counter()
        for mix in mixes:                      # cold pass: all compiles land
            round_(mix)
        warm_ms = (time.perf_counter() - t_warm0) * 1e3
        t0 = time.perf_counter()
        for mix in mixes:                      # warm pass: steady-state wall
            round_(mix)
        warm_s = time.perf_counter() - t0
        exec_ms = warm_s * 1e3 / n_rounds
        waste = inst.pad_tokens / max(inst.real_tokens + inst.pad_tokens, 1)
        real_toks = sum(sum(mix) for mix in mixes)
        name = "packed" if packed else "padded"
        rows.add(f"kernels/fragment/{name}", exec_ms * 1e3,
                 f"fragment_exec_ms={exec_ms:.3f};"
                 f"padding_waste_frac={waste:.4f};"
                 f"recompile_count={inst.n_compiles};"
                 f"tokens_s={real_toks/max(warm_s, 1e-9):.0f};"
                 f"cold_ms={warm_ms:.1f};rounds={n_rounds}")


def main(argv=None) -> int:
    """CLI for CI smokes: ``python -m benchmarks.bench_kernels --quick
    --only fragment`` runs one packed mixed-length batch through the
    real FragmentInstance so kernel-wiring breakage fails the blocking
    tier, not the slow one."""
    import argparse
    ap = argparse.ArgumentParser(prog="benchmarks.bench_kernels")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="",
                    help="'fragment' runs just the ragged-execution bench")
    args = ap.parse_args(argv)
    rows = Rows()
    print("name,us_per_call,derived")
    if args.only == "fragment":
        run_fragment(rows, quick=args.quick)
    else:
        run(rows, quick=args.quick)
    rows.emit()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Kernel micro-benchmarks (CPU wall time of the jnp reference paths; the
Pallas kernels are TPU-target and validated in interpret mode, so wall time
here tracks the reference implementations the dry-run lowers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from benchmarks.common import Rows


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(rows: Rows, *, quick=False) -> None:
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = (1, 512, 4, 2, 64) if quick else (2, 1024, 8, 2, 64)
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, hd), jnp.float32)

    naive = jax.jit(lambda a, b, c: ref.ref_attention(a, b, c, causal=True))
    chunked = jax.jit(lambda a, b, c: ref.chunked_attention(
        a, b, c, causal=True, q_chunk=256))
    us_n = _time(naive, q, k, v)
    us_c = _time(chunked, q, k, v)
    flops = 4 * B * S * S * H * hd / 2
    rows.add("kernels/attn_naive", us_n,
             f"gflops_s={flops/us_n/1e3:.1f}")
    rows.add("kernels/attn_chunked", us_c,
             f"gflops_s={flops/us_c/1e3:.1f};vs_naive={us_n/us_c:.2f}x")

    T, Hh, hdd = (256, 2, 32) if quick else (1024, 4, 64)
    r = jax.random.normal(key, (B, T, Hh, hdd)) * 0.5
    kk = jax.random.normal(key, (B, T, Hh, hdd)) * 0.5
    vv = jax.random.normal(key, (B, T, Hh, hdd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(key, (B, T, Hh, hdd))) * 0.8 + 0.1
    u = jax.random.normal(key, (Hh, hdd)) * 0.1
    s0 = jnp.zeros((B, Hh, hdd, hdd), jnp.float32)
    f_scan = jax.jit(lambda *a: ref.ref_wkv6(*a))
    f_chnk = jax.jit(lambda *a: ref.chunked_wkv6(*a))
    us_s = _time(f_scan, r, kk, vv, w, u, s0)
    us_k = _time(f_chnk, r, kk, vv, w, u, s0)
    rows.add("kernels/wkv6_token_scan", us_s, "impl=lax.scan_per_token")
    rows.add("kernels/wkv6_chunked", us_k,
             f"impl=matmul_chunks;vs_scan={us_s/us_k:.2f}x")

"""Fig. 11: resource with vs without re-partitioning (5 random fragments);
Fig. 12: re-partition point & GPU share under varying bandwidth / rate."""
from __future__ import annotations

import numpy as np

from repro.core import Fragment, realign, solo_plan
from repro.core.repartition import GroupPlan
from repro.serving.neurosurgeon import partition
from repro.data.traces import synth_5g_trace

from benchmarks.common import Rows, book, rate_for, timed, PAPER_MODELS


def _random_frags(model, b, n=5, seed=0):
    prof = b[model]
    L = prof.costs.n_layers
    costs = prof.costs
    rng = np.random.RandomState(seed)
    tr = synth_5g_trace(seconds=600, seed=seed + 900)
    out = []
    slo = 0.95 * costs.mobile_latency_ms("nano", L)
    for i in range(n):
        bw = tr.at(float(rng.randint(0, 600)))
        d = partition(prof, "nano", bw, slo)
        if d.p >= L:
            continue
        out.append(Fragment(model, d.p, max(d.budget_ms, 1.0),
                            rate_for(model), client=f"r{i}"))
    return out


def run(rows: Rows, *, quick=False, seeds=(1, 2, 3, 4, 5)) -> None:
    b = book()
    seeds = seeds[:2] if quick else seeds
    for model in PAPER_MODELS:
        ratios = []
        us = 0.0
        for seed in seeds:
            frags = _random_frags(model, b, n=5, seed=seed)
            if not frags:
                continue
            with timed() as tb:
                with_rp, _ = realign(frags, b[model])
            us = tb["us"]
            without = sum(s.resource for s in
                          filter(None, (solo_plan(f, b[model])
                                        for f in frags)))
            if without > 0 and np.isfinite(with_rp):
                ratios.append(with_rp / without)
        if ratios:
            red = 100 * (1 - float(np.mean(ratios)))
            rows.add(f"repartition/fig11/{model}", us,
                     f"reduction_pct={red:.1f}")

    # Fig. 12: one varying fragment against four fixed ones (inc)
    prof = b["inc"]
    L = prof.costs.n_layers
    fixed = _random_frags("inc", b, n=4, seed=9)
    for bw_mbps in ([20, 200] if quick else [10, 50, 100, 200, 400]):
        slo = 0.95 * prof.costs.mobile_latency_ms("nano", L)
        d = partition(prof, "nano", bw_mbps * 1e6 / 8, slo)
        if d.p >= L:
            continue
        varying = Fragment("inc", d.p, max(d.budget_ms, 1.0), 30.0,
                           client="vary")
        with timed() as tb:
            res, plans = realign(fixed + [varying], prof)
        rp = [p.repartition_point for p in plans
              if isinstance(p, GroupPlan)
              and any(f.client == "vary" for f in p.fragments)]
        rows.add(f"repartition/fig12/bw_{bw_mbps}mbps", tb["us"],
                 f"p={d.p};repartition_point={rp[0] if rp else -1};"
                 f"resource={res:.0f}")
    for rate in ([15, 60] if quick else [5, 15, 30, 60, 120]):
        varying = Fragment("inc", 3, 80.0, float(rate), client="vary")
        with timed() as tb:
            res, plans = realign(fixed + [varying], prof)
        rows.add(f"repartition/fig12/rate_{rate}rps", tb["us"],
                 f"resource={res:.0f}")

"""Online serving controller vs replan-from-scratch (tentpole bench).

Both modes run the SAME control loop (sliding-window estimation, the same
replan triggers) over volatile 30 s traces; the difference is what a
replan costs: the controller plans incrementally (shadow reuse) and
applies a plan diff so surviving pools keep warm instances, while the
scratch baseline runs the full scheduler and redeploys every pool (each
paying instance startup). Reports SLO attainment, drop rate, and mean
replan latency."""
from __future__ import annotations

import numpy as np

from repro.core import GraftPlanner
from repro.core.reuse import IncrementalPlanner
from repro.serving import (ServingController, fleet_fragments, make_fleet,
                           simulate)

from benchmarks.common import Rows, book, rate_for

VOLATILE = {"sigma": 0.6, "fade_prob": 0.05}


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    duration = 10.0 if quick else 30.0
    for model in (("inc",) if quick else ("inc", "mob", "vit")):
        fleet = make_fleet(model, b, n_nano=8, rate=rate_for(model),
                           seed=17, trace_kw=VOLATILE)
        frags0 = fleet_fragments(fleet, b, t=0.0)
        if not frags0:
            continue
        derived = {}
        for mode in ("controller", "scratch"):
            diffs = mode == "controller"
            planner = IncrementalPlanner(b) if diffs else GraftPlanner(b)
            ctl = ServingController(b, planner=planner, apply_diffs=diffs)
            plan0 = ctl.bootstrap(frags0)
            res = simulate(plan0, fleet, b, duration_s=duration, t0=0.0,
                           controller=ctl, seed=3)
            derived[mode] = (res.attainment(), res.drop_rate(),
                             ctl.mean_replan_ms(), ctl.stats)
            rows.add(f"controller/{model}/{mode}",
                     ctl.mean_replan_ms() * 1e3,
                     f"slo_attainment={res.attainment():.3f};"
                     f"drop_rate={res.drop_rate():.3f};"
                     f"replans={ctl.stats['replans']};"
                     f"pools_kept={ctl.stats['pools_kept']};"
                     f"pools_added={ctl.stats['pools_added']}")
        (a_c, d_c, l_c, _), (a_s, d_s, l_s, _) = (derived["controller"],
                                                  derived["scratch"])
        rows.add(f"controller/{model}/delta", 0.0,
                 f"attainment_gain={a_c - a_s:+.3f};"
                 f"drop_gain={d_s - d_c:+.3f};"
                 f"replan_speedup={l_s / max(l_c, 1e-9):.1f}x")

    # ---- instance_startup_ms sweep: where does diffing stop mattering? --
    # Plan diffing's edge is warm instances surviving a replan; on
    # hardware with near-instant instance (re)starts the scratch redeploy
    # catches up. Chart attainment gain vs startup cost to find the
    # crossover (ROADMAP item: "fast-restart hardware").
    sweep = (0.0, 200.0, 1600.0) if quick \
        else (0.0, 50.0, 200.0, 800.0, 3200.0)
    model = "inc"
    fleet = make_fleet(model, b, n_nano=8, rate=rate_for(model),
                       seed=17, trace_kw=VOLATILE)
    frags0 = fleet_fragments(fleet, b, t=0.0)
    crossover = None
    for startup in sweep:
        att = {}
        for mode in ("controller", "scratch"):
            diffs = mode == "controller"
            planner = IncrementalPlanner(b) if diffs else GraftPlanner(b)
            ctl = ServingController(b, planner=planner, apply_diffs=diffs)
            plan0 = ctl.bootstrap(frags0)
            res = simulate(plan0, fleet, b, duration_s=duration, t0=0.0,
                           controller=ctl, seed=3,
                           instance_startup_ms=startup)
            att[mode] = res.attainment()
        gain = att["controller"] - att["scratch"]
        if crossover is None and gain > 0.02:
            crossover = startup
        rows.add(f"controller/startup_sweep/{int(startup)}", 0.0,
                 f"attainment_controller={att['controller']:.3f};"
                 f"attainment_scratch={att['scratch']:.3f};"
                 f"attainment_gain={gain:+.3f}")
    rows.add("controller/startup_sweep/crossover", 0.0,
             f"first_startup_ms_with_gain="
             f"{crossover if crossover is not None else 'none'}")

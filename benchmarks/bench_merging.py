"""Figs 13-15: merging strategies (none / uniform / uniform+), threshold
sensitivity, and fragment-count reduction."""
from __future__ import annotations

import numpy as np

from repro.core import Fragment, GraftPlanner, merge

from benchmarks.common import Rows, book, rate_for, timed, PAPER_MODELS


def _frag_population(model, b, n=50, seed=0):
    """n fragments with realistic clustering: a few popular partition points,
    budget jitter (the situation merging exploits)."""
    rng = np.random.RandomState(seed)
    L = b[model].costs.n_layers
    n_pts = max(L // 2, 2)
    pts = rng.choice(n_pts, size=min(4, n_pts), replace=False)
    out = []
    for i in range(n):
        p = int(rng.choice(pts))
        base_t = 60.0 + 6.0 * p
        # budgets are bandwidth-driven and therefore continuous: a third of
        # the fleet shares quantized budgets (stable networks -> uniform,
        # mergeable), the rest jitter continuously (what re-alignment, not
        # uniform merging, has to handle)
        if rng.rand() < 0.33:
            t = base_t * (1.0 + 0.02 * rng.randint(0, 3))
        else:
            t = base_t * (1.0 + 0.15 * rng.rand())
        out.append(Fragment(model, p, t, rate_for(model), client=f"m{i}"))
    return out


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    n = 20 if quick else 50
    for model in PAPER_MODELS:
        frags = _frag_population(model, b, n=n, seed=3)
        base = None
        for strat, thr in (("none", 0.0), ("uniform", 0.0),
                           ("uniform+", 0.2)):
            with timed() as tb:
                plan = GraftPlanner(b, merge_strategy=strat,
                                    merging_threshold=thr).plan(frags)
            res = plan.total_resource
            if strat == "none":
                base = res
            rel = res / base if base else 1.0
            rows.add(f"merging/fig13/{model}/{strat}", tb["us"],
                     f"resource={res:.0f};vs_none={rel:.3f};"
                     f"n_after_merge={plan.n_fragments_merged}")
        # Fig. 15a: threshold sweep
        for thr in ([0.1, 0.4] if quick else [0.05, 0.1, 0.2, 0.3, 0.4]):
            with timed() as tb:
                plan = GraftPlanner(b, merging_threshold=thr).plan(frags)
            rows.add(f"merging/fig15/{model}/thr_{thr}", tb["us"],
                     f"resource={plan.total_resource:.0f};"
                     f"n_after_merge={plan.n_fragments_merged}")

"""Decode serving: continuous batching vs waved close-on-flush.

Both phases run the SAME burst of autoregressive streams through the
event-driven server over one full-range pool with a paged KV arena —
the only difference is admission policy:

  * **continuous** — ``decode_continuous=True``: new streams join the
    RUNNING decode batch at step boundaries, the moment a slot (and KV
    blocks) free up. TTFT is bounded by one step + one solo prefill.
  * **waved** — ``decode_continuous=False``: a new wave is admitted only
    once the previous batch fully drains, so a stream arriving just
    after a wave starts waits out every resident stream's full decode.

The headline derived keys — ``ttft_ms`` / ``tpot_ms`` /
``kv_block_util_frac`` on the ``decode/serve/continuous`` row — are
extracted by ``benchmarks.gate`` and BLOCK in ``scripts/ci.sh`` once
baselined. The win condition the gate protects: continuous beats waved
on TTFT at equal-or-better tokens/s.

A third row exercises the arena's cross-request prefix sharing: the
same prompt decoded back-to-back must hit the retained block index
instead of re-prefilling.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import Rows


def _run_phase(cfg, book, params, frags, *, continuous: bool,
               n_requests: int, seq_len: int, lens: tuple) -> dict:
    from repro.serving.executor import GraftExecutor, ServeRequest
    from repro.serving.server import GraftServer
    from repro.serving.transport import InProcessTransport
    from repro.serving.smoke import decode_plan

    plan = decode_plan(cfg, book, frags, batch=4)
    ex = GraftExecutor(plan, params, cfg, transport=InProcessTransport(),
                       decode_ctx=64, kv_blocks=96, kv_block_tokens=4)
    server = GraftServer(ex, book=book,
                         decode_continuous=continuous).start()
    rng = np.random.RandomState(7)
    util_samples: list = []
    stop_polling = threading.Event()

    def poll_util():
        # the deploy handle is a separate channel from the driver's, and
        # PoolService serializes dispatch, so polling mid-run is safe
        while not stop_polling.is_set():
            for s in ex.pool_stats().values():
                kv = s.get("kv")
                if kv and kv["free_blocks"] < kv["n_blocks"]:
                    util_samples.append(kv["util_frac"])
            time.sleep(0.01)

    try:
        # warmup: pay the solo-prefill + batched-step compiles off-clock
        w = ServeRequest(client=frags[0].client,
                         tokens=rng.randint(0, cfg.vocab_size,
                                            seq_len).astype(np.int32),
                         max_new_tokens=2, tpot_budget_ms=1e6)
        server.submit(w, 0, 1e6)
        assert server.join(timeout=600.0)
        mark = server.mark()
        poller = threading.Thread(target=poll_util, daemon=True)
        poller.start()
        t0 = time.monotonic()
        for i in range(n_requests):
            f = frags[i % len(frags)]
            # varied decode lengths are the point: slots free at different
            # steps, so continuous admission can backfill mid-batch while
            # waved admission must wait for the longest stream
            req = ServeRequest(client=f.client,
                               tokens=rng.randint(0, cfg.vocab_size,
                                                  seq_len).astype(np.int32),
                               max_new_tokens=int(lens[i % len(lens)]),
                               tpot_budget_ms=1e6)
            server.submit(req, 0, 1e6)
            time.sleep(0.012)
        assert server.join(timeout=600.0), "decode bench never drained"
        wall_s = time.monotonic() - t0
        stop_polling.set()
        recs = [r for r in server.records(since=mark) if r.get("decode")]
    finally:
        stop_polling.set()
        server.stop(drain=False, timeout=10.0)
        ex.close()
    ttft = np.array([r["ttft_ms"] for r in recs])
    tpot = np.array([r["tpot_ms"] for r in recs if r["n_tokens"] > 1]
                    or [0.0])
    toks = int(sum(r["n_tokens"] for r in recs))
    return {
        "n": len(recs),
        "wall_s": wall_s,
        "ttft_ms": float(np.mean(ttft)),
        "ttft_p99_ms": float(np.percentile(ttft, 99)),
        "tpot_ms": float(np.mean(tpot)),
        "toks_s": toks / max(wall_s, 1e-9),
        "kv_block_util_frac": float(np.mean(util_samples))
        if util_samples else 0.0,
    }


def _run_disagg(cfg, book, params, frags, *, n_requests: int,
                seq_len: int, lens: tuple) -> dict:
    """Disaggregated phase: the full-range pool is prefill-role, a
    decode-role pool is fed KV blocks over the transport. Same burst as
    `_run_phase`; the extra derived keys are the handoff cost
    (``kv_handoff_ms``, the admit wall time when a KV frame rides the
    hop) and the TTFT stamped at the prefill pool's first token."""
    from repro.serving.executor import GraftExecutor, ServeRequest
    from repro.serving.server import GraftServer
    from repro.serving.transport import InProcessTransport
    from repro.serving.smoke import disagg_plan

    plan = disagg_plan(cfg, book, frags, batch=4)
    ex = GraftExecutor(plan, params, cfg, transport=InProcessTransport(),
                       decode_ctx=64, kv_blocks=96, kv_block_tokens=4,
                       decode_disagg=True)
    server = GraftServer(ex, book=book).start()
    rng = np.random.RandomState(7)
    try:
        w = ServeRequest(client=frags[0].client,
                         tokens=rng.randint(0, cfg.vocab_size,
                                            seq_len).astype(np.int32),
                         max_new_tokens=2, tpot_budget_ms=1e6)
        server.submit(w, 0, 1e6)
        assert server.join(timeout=600.0)
        mark = server.mark()
        t0 = time.monotonic()
        for i in range(n_requests):
            f = frags[i % len(frags)]
            req = ServeRequest(client=f.client,
                               tokens=rng.randint(0, cfg.vocab_size,
                                                  seq_len).astype(np.int32),
                               max_new_tokens=int(lens[i % len(lens)]),
                               tpot_budget_ms=1e6)
            server.submit(req, 0, 1e6)
            time.sleep(0.012)
        assert server.join(timeout=600.0), "disagg bench never drained"
        wall_s = time.monotonic() - t0
        recs = [r for r in server.records(since=mark) if r.get("decode")]
        rep = server.report()
    finally:
        server.stop(drain=False, timeout=10.0)
        ex.close()
    ttft = np.array([r["ttft_ms"] for r in recs])
    tpot = np.array([r["tpot_ms"] for r in recs if r["n_tokens"] > 1]
                    or [0.0])
    toks = int(sum(r["n_tokens"] for r in recs))
    return {
        "n": len(recs),
        "wall_s": wall_s,
        "ttft_ms": float(np.mean(ttft)),
        "ttft_p99_ms": float(np.percentile(ttft, 99)),
        "tpot_ms": float(np.mean(tpot)),
        "toks_s": toks / max(wall_s, 1e-9),
        "kv_handoffs": int(rep["kv_handoffs"]),
        "kv_handoff_ms": float(rep["kv_handoff_ms"]),
        "decode_local": int(rep["decode_local"]),
    }


def _prefix_reuse(cfg, book, params, frags, *, seq_len: int) -> dict:
    """Same prompt, back-to-back streams: the second admission must hit
    the retained prefix index instead of re-prefilling."""
    from repro.serving.executor import GraftExecutor
    from repro.serving.transport import InProcessTransport
    from repro.serving.smoke import decode_plan

    plan = decode_plan(cfg, book, frags, batch=2)
    ex = GraftExecutor(plan, params, cfg, transport=InProcessTransport(),
                       decode_ctx=64, kv_blocks=32, kv_block_tokens=4)
    try:
        key = next(iter(ex.pool_specs()))
        handle = ex.handle(key)
        rng = np.random.RandomState(11)
        toks = rng.randint(0, cfg.vocab_size, seq_len).astype(np.int32)
        sig = (cfg.name, 0, 0)
        for rid in (1, 2):
            r = handle.decode_admit(rid, "c0", toks, 3, sig=sig)
            assert r["admitted"]
            while True:
                rep = handle.decode_step()
                if any(ev.get("done") for ev in rep["events"]):
                    break
        kv = handle.stats()["kv"]
    finally:
        ex.close()
    return kv


def run(rows: Rows, quick: bool = False) -> None:
    from repro.serving.smoke import smoke_fragments, smoke_setup

    seq_len = 12
    lens = (3, 5, 8, 12) if quick else (3, 5, 8, 12, 16, 20)
    n_requests = 10 if quick else 16
    cfg, book, params = smoke_setup(seq_len=seq_len, seed=0)
    frags = smoke_fragments(cfg, 3, seed=0)

    results = {}
    for mode, continuous in (("continuous", True), ("waved", False)):
        t0 = time.perf_counter()
        r = _run_phase(cfg, book, params, frags, continuous=continuous,
                       n_requests=n_requests, seq_len=seq_len, lens=lens)
        results[mode] = r
        rows.add(f"decode/serve/{mode}",
                 (time.perf_counter() - t0) * 1e6 / max(r["n"], 1),
                 f"ttft_ms={r['ttft_ms']:.2f}"
                 f";ttft_p99_ms={r['ttft_p99_ms']:.2f}"
                 f";tpot_ms={r['tpot_ms']:.2f}"
                 f";toks_s={r['toks_s']:.1f}"
                 f";kv_block_util_frac={r['kv_block_util_frac']:.4f}"
                 f";n={r['n']}")
    c, w = results["continuous"], results["waved"]
    rows.add("decode/win", 0.0,
             f"ttft_ratio={c['ttft_ms'] / max(w['ttft_ms'], 1e-9):.3f}"
             f";toks_ratio={c['toks_s'] / max(w['toks_s'], 1e-9):.3f}")

    t0 = time.perf_counter()
    dg = _run_disagg(cfg, book, params, frags, n_requests=n_requests,
                     seq_len=seq_len, lens=lens)
    rows.add("decode/serve/disagg",
             (time.perf_counter() - t0) * 1e6 / max(dg["n"], 1),
             f"ttft_ms={dg['ttft_ms']:.2f}"
             f";ttft_p99_ms={dg['ttft_p99_ms']:.2f}"
             f";tpot_ms={dg['tpot_ms']:.2f}"
             f";toks_s={dg['toks_s']:.1f}"
             f";kv_handoff_ms={dg['kv_handoff_ms']:.2f}"
             f";kv_handoffs={dg['kv_handoffs']}"
             f";decode_local={dg['decode_local']}"
             f";n={dg['n']}")

    kv = _prefix_reuse(cfg, book, params, frags, seq_len=seq_len)
    rows.add("decode/prefix/reuse", 0.0,
             f"prefix_hits={kv['prefix_hits']}"
             f";prefix_tokens_reused={kv['prefix_tokens_reused']}"
             f";evictions={kv['evictions']}"
             f";cow_copies={kv['cow_copies']}")


def main(argv=None) -> int:
    """Standalone entry: ``python -m benchmarks.bench_decode --disagg``
    runs just the disaggregated phase and prints its derived keys —
    handy for iterating on the handoff path without the full suite."""
    import argparse

    from repro.serving.smoke import smoke_fragments, smoke_setup

    ap = argparse.ArgumentParser(prog="benchmarks.bench_decode")
    ap.add_argument("--disagg", action="store_true",
                    help="run only the disaggregated prefill/decode phase")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    rows = Rows()
    if args.disagg:
        seq_len = 12
        lens = (3, 5, 8, 12) if args.quick else (3, 5, 8, 12, 16, 20)
        n_requests = 10 if args.quick else 16
        cfg, book, params = smoke_setup(seq_len=seq_len, seed=0)
        frags = smoke_fragments(cfg, 3, seed=0)
        t0 = time.perf_counter()
        dg = _run_disagg(cfg, book, params, frags, n_requests=n_requests,
                         seq_len=seq_len, lens=lens)
        rows.add("decode/serve/disagg",
                 (time.perf_counter() - t0) * 1e6 / max(dg["n"], 1),
                 f"ttft_ms={dg['ttft_ms']:.2f}"
                 f";ttft_p99_ms={dg['ttft_p99_ms']:.2f}"
                 f";tpot_ms={dg['tpot_ms']:.2f}"
                 f";toks_s={dg['toks_s']:.1f}"
                 f";kv_handoff_ms={dg['kv_handoff_ms']:.2f}"
                 f";kv_handoffs={dg['kv_handoffs']}"
                 f";decode_local={dg['decode_local']}"
                 f";n={dg['n']}")
    else:
        run(rows, quick=args.quick)
    rows.emit()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

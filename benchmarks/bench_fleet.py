"""GraftFleet: front-end scale-out + shed-vs-record under overload.

Two claims, both on ONE shared pool fleet behind a realtime shaped
transport (every client uplink actually sleeps its transfer time — the
network-bound regime the paper budgets for):

  * **scale-out** — 2 front-ends sustain higher offered load than 1 at
    equal SLO attainment. The single front-end serializes every client's
    uplink submit through one channel per pool and every mobile part
    through one ingest path; front-ends overlap both.
  * **overload** — at ~2x the measured 1-FE sustainable load, the
    admission-control/drop-shed policy keeps p99 of *admitted* requests
    inside the SLO, while the no-shed baseline (today's record-lateness
    behavior) blows it for everyone.

``--remote`` (or suite ``fleet_remote``) adds the REMOTE data-path
claim: with pools in worker subprocesses, per-front-end dial-back
channels (``RemoteExecutor.open_handle``) beat the shared-channel
baseline on p99 at equal paced offered load — two front-ends' shaped
uplink transfers overlap on separate TCP lanes instead of queueing on
the one worker connection.

``--skew`` (or suite ``router``) adds the GLOBAL-ROUTING claim: one hot
client at 10x the offered load of the rest, weighted router (live
load/affinity signals + work stealing) vs the static HRW ring at equal
fleet size. HRW pins the hot client's front-end while the other idles;
the weighted router moves the other clients off the hot front-end and
the balancer steals the hot client's own queued overflow, so
p99-of-admitted drops at equal attainment.

Rows:
  fleet/throughput/feN     us = makespan; derived rps + attainment
  fleet/scaleout           derived ratio = thr(2fe)/thr(1fe)
  fleet/overload/noshed    derived p99/attainment at 2x load, no policy
  fleet/overload/shed      derived p99-of-admitted/attainment/shed_rate
  fleet/skew/hrw           us = p99; static ring under hot-client skew
  fleet/skew/weighted      us = p99; weighted router + stealing, same load
  fleet/skew/win           derived p99_hrw/p99_weighted ratio
  fleet/remote/shared      us = p99; one worker connection per pool
  fleet/remote/perfe       us = p99; one dial-back lane per front-end
  fleet/remote/win         derived p99_shared/p99_perfe ratio
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows

BUDGET_MS = 150.0


def _spread_clients(n, fes):
    """Client names that rendezvous-route evenly across ``fes``,
    returned grouped per front-end so workload mixes can be balanced."""
    from repro.serving.fleet import rendezvous_route
    per = n // len(fes)
    got = {fe: [] for fe in fes}
    i = 0
    while min(len(v) for v in got.values()) < per and i < 10_000:
        name = f"cl{i}"
        fe = rendezvous_route(name, fes)
        if len(got[fe]) < per:
            got[fe].append(name)
        i += 1
    return got


def _setup(n_clients):
    """Every front-end gets the SAME workload mix (alternating p within
    its client group) so a 2-FE run genuinely splits the expensive p=1
    uplink traffic instead of depending on hash luck."""
    from repro.core import Fragment
    from repro.serving.smoke import mixed_depth_plan, smoke_setup
    cfg, book, params = smoke_setup("qwen3-1.7b", seed=0, n_layers=3)
    groups = _spread_clients(n_clients, ["fe0", "fe1"])
    frags = [Fragment(cfg.name, p=j % 2, t=BUDGET_MS, q=30.0, client=c)
             for fe in sorted(groups) for j, c in enumerate(groups[fe])]
    plan = mixed_depth_plan(cfg, book, frags, s=1, batch=4)
    return cfg, book, params, frags, plan


def _shaped(frags, *, xfer_ms=25.0, rtt_ms=6.0):
    """Constant-bandwidth realtime shaping: every p=1 uplink pays
    ~xfer_ms of wall clock, so serving is genuinely network-bound."""
    from repro.data.traces import BandwidthTrace
    from repro.serving.transport import (InProcessTransport, LinkShape,
                                         ShapedTransport)
    payload = 16 * 256 * 4                       # (S=16, d=256) fp32
    bw = payload / (xfer_ms / 1e3)
    shapes = {f.client: LinkShape(
        trace=BandwidthTrace(samples=np.full(600, bw)), rtt_ms=rtt_ms)
        for f in frags}
    return ShapedTransport(InProcessTransport(), shapes, realtime=True)


def _reqs(cfg, frags, rng, n_waves):
    from repro.serving import ServeRequest
    return [(ServeRequest(client=f.client, tokens=rng.randint(
        0, cfg.vocab_size, 16).astype(np.int32)), f.p)
        for _ in range(n_waves) for f in frags]


def _fleet(plan, params, cfg, book, frags, n_fe, shed_policy=None,
           router="weighted"):
    from repro.serving import GraftExecutor, GraftFleet
    ex = GraftExecutor(plan, params, cfg, transport=_shaped(frags))
    _prewarm_shapes(ex, cfg, np.random.RandomState(99))
    # 2 ingest threads per front-end: enough to overlap mobile parts
    # with uplink sleeps without thrashing small CI boxes
    fleet = GraftFleet(ex, n_frontends=n_fe, book=book, ingest_threads=2,
                       shed_policy=shed_policy, router=router,
                       flush_safety_frac=0.25).start()
    return ex, fleet


def _prewarm_shapes(ex, cfg, rng):
    """Compile every (pool, bucket) batch shape up front: a mid-run jit
    trace (~100s of ms on a small box) would poison the exec EWMAs that
    every flush deadline and admission estimate runs on."""
    from repro.serving import ServeRequest
    from repro.serving.batcher import bucket_size
    for key, spec in ex.pool_specs().items():
        req = ServeRequest(client="_warm", tokens=rng.randint(
            0, cfg.vocab_size, 16).astype(np.int32))
        payload = ex.mobile_part(req, key[1])
        h = ex.handle(key)
        for b in sorted({bucket_size(n, max(spec.batch, 1))
                         for n in range(1, max(spec.batch, 1) + 1)}):
            h.execute([(ex.next_rid(), "_warm", payload, None)
                       for _ in range(b)])


def _warm(fleet, cfg, frags, rng):
    # roomy-but-finite budget: nothing is hopeless during warmup (so a
    # shed policy can't eat the compile-paying requests and EWMAs learn
    # real costs), yet partial batches still flush on deadline
    for req, p in _reqs(cfg, frags, rng, 2):
        fleet.submit(req, p, 250.0)
    if not fleet.join(timeout=600.0):
        raise RuntimeError("fleet warmup never drained")


def _burst(fleet, cfg, frags, rng, waves, budget_ms):
    """Submit ``waves`` waves as fast as possible; -> (makespan_s, report)."""
    mark = fleet.mark()
    reqs = _reqs(cfg, frags, rng, waves)
    t0 = time.perf_counter()
    for req, p in reqs:
        fleet.submit(req, p, budget_ms)
    if not fleet.join(timeout=600.0):
        raise RuntimeError("burst never drained")
    return time.perf_counter() - t0, fleet.report(since=mark)


def run_remote(rows: Rows, *, quick=False) -> None:
    """Per-front-end dial-back channels vs the shared worker connection,
    REMOTE pools (worker subprocesses), equal paced offered load."""
    from repro.serving import GraftFleet
    from repro.serving.remote import RemoteExecutor
    from repro.serving.transport import ShapedTransport, SocketTransport

    n_clients = 4
    cfg, book, params, frags, plan = _setup(n_clients)
    rng = np.random.RandomState(0)
    secs = 1.5 if quick else 3.0
    # pace between the two regimes: one wave's p=1 transfers fit the
    # period when they OVERLAP (per-FE lanes), not when they serialize
    # on the one worker connection — so equal offered load separates the
    # configurations on tail latency alone
    n_p1 = sum(1 for f in frags if f.p == 1)
    period = 25.0e-3 * (n_p1 + 1) / 2.0
    p99 = {}
    for label, per_fe in (("shared", False), ("perfe", True)):
        tp = ShapedTransport(SocketTransport(), _shaped(frags).shapes,
                             realtime=True)
        ex = RemoteExecutor(plan, params, cfg, transport=tp,
                            per_frontend_channels=per_fe)
        _prewarm_shapes(ex, cfg, np.random.RandomState(99))
        fleet = GraftFleet(ex, n_frontends=2, book=book, ingest_threads=2,
                           flush_safety_frac=0.25).start()
        try:
            _warm(fleet, cfg, frags, rng)
            mark = fleet.mark()
            t_end = time.perf_counter() + secs
            offered = 0
            while time.perf_counter() < t_end:
                t_wave = time.perf_counter()
                for req, p in _reqs(cfg, frags, rng, 1):
                    fleet.submit(req, p, 10_000.0)   # measure, don't shed
                    offered += 1
                time.sleep(max(period - (time.perf_counter() - t_wave),
                               0.0))
            if not fleet.join(timeout=600.0):
                raise RuntimeError("remote paced phase never drained")
            rep = fleet.report(since=mark)
            p99[label] = rep["p99_ms"]
            rows.add(f"fleet/remote/{label}", rep["p99_ms"] * 1e3,
                     f"p99_ms={rep['p99_ms']:.1f};"
                     f"p50_ms={rep['p50_ms']:.1f};"
                     f"offered={offered};"
                     f"offered_rps={offered / secs:.1f};"
                     f"channels={'per-frontend' if per_fe else 'shared'}")
        finally:
            fleet.stop(drain=False, timeout=5.0)
            ex.close()
    rows.add("fleet/remote/win", 0.0,
             f"p99_ratio={p99['shared'] / max(p99['perfe'], 1e-9):.2f}x")


SKEW_BUDGET_MS = 2500.0       # roomy: both arms hold attainment ~1.0, so
                              # the comparison is pure p99-of-admitted


def run_skew(rows: Rows, *, quick=False) -> None:
    """Hot-client skew: ONE client offers 10x the load of each of the
    others, paced so the fleet as a whole can keep up but the hot
    client's HRW front-end alone cannot. The static ring pins the hot
    client (and its hash-share of the others) to one front-end; the
    weighted router moves the others off the hot front-end as its queue
    depth rises, and the balancer steals the hot client's own queued
    overflow to the idle peer."""
    from itertools import count
    from repro.core import Fragment
    from repro.serving import ServeRequest
    from repro.serving.batcher import ShedPolicy
    from repro.serving.fleet import rendezvous_route
    from repro.serving.smoke import mixed_depth_plan, smoke_setup

    fes = ["fe0", "fe1"]
    hot = next(f"hot{i}" for i in count()
               if rendezvous_route(f"hot{i}", fes) == "fe0")
    groups = _spread_clients(4, fes)          # 2 normals per front-end
    normals = sorted(groups["fe0"] + groups["fe1"])
    cfg, book, params = smoke_setup("qwen3-1.7b", seed=0, n_layers=3)
    frags = [Fragment(cfg.name, p=1, t=SKEW_BUDGET_MS, q=100.0,
                      client=hot)] + \
            [Fragment(cfg.name, p=1, t=SKEW_BUDGET_MS, q=10.0, client=c)
             for c in normals]
    # batch=1: every item flushes as soon as its driver frees up, so
    # latency is pure queueing (the uplink transfers serialize per
    # channel regardless of batch size). With batch>1 a final-wave
    # remainder batch waits out its full EDF flush slack (~budget), and
    # that one straggler IS the p99 — an artifact of wave arithmetic,
    # not of routing quality.
    plan = mixed_depth_plan(cfg, book, frags, s=1, batch=1)
    waves = 6 if quick else 10
    # one wave = 10 hot + 4 normal p=1 uplinks at ~25 ms each: 350 ms of
    # transfer per wave over two per-front-end channels fits a 200 ms
    # period only when balanced — the hot front-end alone (250 ms+) can't
    period_s = 0.2
    rng = np.random.RandomState(0)
    p99 = {}
    for label in ("hrw", "weighted"):
        pol = ShedPolicy(budget_frac=0.9, window=64)
        ex, fleet = _fleet(plan, params, cfg, book, frags, 2,
                           shed_policy=pol, router=label)
        try:
            _warm(fleet, cfg, frags, rng)
            mark = fleet.mark()
            for _ in range(waves):
                t_wave = time.perf_counter()
                for client in [hot] * 10 + normals:
                    req = ServeRequest(client=client, tokens=rng.randint(
                        0, cfg.vocab_size, 16).astype(np.int32))
                    fleet.submit(req, 1, SKEW_BUDGET_MS)
                time.sleep(max(period_s - (time.perf_counter() - t_wave),
                               0.0))
            if not fleet.join(timeout=600.0):
                raise RuntimeError("skew phase never drained")
            rep = fleet.report(since=mark)
            p99[label] = rep["p99_ms"]
            shed_rate = rep["shed"] / max(rep["offered"], 1)
            served = "+".join(str(rep["frontends"][fe]["served"])
                              for fe in sorted(rep["frontends"]))
            rstats = fleet.router.stats if fleet.router is not None else {}
            rows.add(f"fleet/skew/{label}", rep["p99_ms"] * 1e3,
                     f"p99_ms={rep['p99_ms']:.1f};"
                     f"attainment={rep['attainment']:.3f};"
                     f"offered={rep['offered']};"
                     f"shed_rate={shed_rate:.2f};"
                     f"steals={rep['steals']};"
                     f"fe_served={served};"
                     f"moves={rstats.get('moves', 0)};"
                     f"fallback={rstats.get('fallback_hrw', 0)};"
                     f"hot_x=10")
        finally:
            fleet.stop(drain=False, timeout=5.0)
            ex.close()
    rows.add("fleet/skew/win", 0.0,
             f"p99_ratio={p99['hrw'] / max(p99['weighted'], 1e-9):.2f}x")


def run(rows: Rows, *, quick=False) -> None:
    from repro.serving.batcher import ShedPolicy

    n_clients = 4 if quick else 6
    waves = 4 if quick else 8
    rounds = 3 if quick else 4
    cfg, book, params, frags, plan = _setup(n_clients)
    rng = np.random.RandomState(0)

    # ---- scale-out: same burst, 1 vs 2 front-ends -----------------------
    # a roomy budget keeps attainment ~1.0 for BOTH configs (equal
    # attainment), so the makespan difference is pure sustained-load
    # headroom: what a front-end serializes, two overlap
    thr = {}
    for n_fe in (1, 2):
        ex, fleet = _fleet(plan, params, cfg, book, frags, n_fe)
        try:
            _warm(fleet, cfg, frags, rng)
            best, att = None, 0.0
            for _ in range(rounds):
                span, rep = _burst(fleet, cfg, frags, rng, waves,
                                   budget_ms=1500.0)
                if best is None or span < best:
                    best, att = span, rep["attainment"]
            n_req = waves * len(frags)
            thr[n_fe] = n_req / best
            rows.add(f"fleet/throughput/fe{n_fe}", best * 1e6,
                     f"rps={thr[n_fe]:.1f};attainment={att:.3f};"
                     f"requests={n_req}")
        finally:
            fleet.stop(drain=False, timeout=5.0)
            ex.close()
    ratio = thr[2] / max(thr[1], 1e-9)
    rows.add("fleet/scaleout", 0.0, f"ratio={ratio:.2f}x")

    # ---- overload: 2x the fleet's burst throughput, shed vs record ------
    # burst throughput upper-bounds what the fleet can sustain, so 2x it
    # is overload by construction, not by tuning
    offered_rps = 2.0 * thr[2]
    secs = 2.0 if quick else 4.0
    for label, policy in (("noshed", None),
                          ("shed", ShedPolicy(budget_frac=0.9, window=32))):
        ex, fleet = _fleet(plan, params, cfg, book, frags, 2,
                           shed_policy=policy)
        try:
            _warm(fleet, cfg, frags, rng)
            mark = fleet.mark()
            period = len(frags) / offered_rps    # one wave per period
            t_end = time.perf_counter() + secs
            while time.perf_counter() < t_end:
                t_wave = time.perf_counter()
                for req, p in _reqs(cfg, frags, rng, 1):
                    fleet.submit(req, p, BUDGET_MS)
                time.sleep(max(period - (time.perf_counter() - t_wave), 0.0))
            if not fleet.join(timeout=600.0):
                raise RuntimeError("overload phase never drained")
            rep = fleet.report(since=mark)
            shed_rate = rep["shed"] / max(rep["offered"], 1)
            rows.add(f"fleet/overload/{label}", rep["p99_ms"] * 1e3,
                     f"p99_ms={rep['p99_ms']:.1f};"
                     f"attainment={rep['attainment']:.3f};"
                     f"slo_ms={BUDGET_MS:.0f};"
                     f"offered={rep['offered']};"
                     f"shed_rate={shed_rate:.2f}")
        finally:
            fleet.stop(drain=False, timeout=5.0)
            ex.close()


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--remote", action="store_true",
                    help="run the remote per-front-end-channel claim "
                         "(worker subprocesses) instead of the "
                         "in-process scale-out/overload suites")
    ap.add_argument("--skew", action="store_true",
                    help="run the hot-client skew claim (weighted router "
                         "vs HRW ring) instead of the default suites")
    args = ap.parse_args()
    rows = Rows()
    print("name,us_per_call,derived")
    fn = run_remote if args.remote else run_skew if args.skew else run
    fn(rows, quick=args.quick)
    rows.emit()

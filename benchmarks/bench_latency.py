"""Figs 8-10: end-to-end latency distributions & SLO compliance."""
from __future__ import annotations

import numpy as np

from repro.core import GraftPlanner, plan_gslice, plan_static
from repro.serving import fleet_fragments, simulate

from benchmarks.common import Rows, book, scenario, timed, PAPER_MODELS


def run(rows: Rows, *, quick=False, duration_s=8.0) -> None:
    b = book()
    models = PAPER_MODELS[:3] if quick else PAPER_MODELS
    for scale in (["small"] if quick else ["small", "small_het", "large"]):
        for model in models:
            fleet, frags = scenario(model, scale, seed=7)
            if not frags:
                continue
            avg = fleet_fragments(fleet, b, t=42.0, use_average_bw=True)
            plans = {
                "graft": GraftPlanner(b).plan(frags),
                "gslice": plan_gslice(frags, b),
                "static": plan_static(frags, b, avg_frags=avg),
            }
            for name, plan in plans.items():
                if not np.isfinite(plan.total_resource):
                    continue
                with timed() as tb:
                    r = simulate(plan, fleet, b, duration_s=duration_s,
                                 t0=42.0,
                                 use_average_partition=(name == "static"))
                lat = r.all_latencies()
                if len(lat) == 0:
                    continue
                p50, p95, p99 = np.percentile(lat, [50, 95, 99])
                rows.add(f"latency/{scale}/{model}/{name}", tb["us"],
                         f"p50={p50:.0f};p95={p95:.0f};p99={p99:.0f};"
                         f"viol={r.violation_rate():.3f}")

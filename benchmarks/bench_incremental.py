"""§6 extension: realignment reuse / shadow instances under trigger storms.

A fleet replans every second over a volatile trace window; compare the
full scheduler against the IncrementalPlanner (paper §6's proposal) on
planning time and resource overhead."""
from __future__ import annotations

import numpy as np

from repro.core import GraftPlanner
from repro.core.reuse import IncrementalPlanner
from repro.serving import fleet_fragments, make_fleet

from benchmarks.common import Rows, book, rate_for, timed


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    for model in (("inc",) if quick else ("inc", "mob", "vit")):
        fleet = make_fleet(model, b, n_nano=12, rate=rate_for(model), seed=9)
        full = GraftPlanner(b)
        inc = IncrementalPlanner(b)
        t_full, t_inc, r_full, r_inc = [], [], [], []
        for t in np.arange(0.0, 30.0, 1.0):
            frags = fleet_fragments(fleet, b, t=float(t))
            if not frags:
                continue
            with timed() as tf:
                pf = full.plan(frags)
            with timed() as ti:
                pi = inc.plan(frags)
            t_full.append(tf["us"]); t_inc.append(ti["us"])
            r_full.append(pf.total_resource); r_inc.append(pi.total_resource)
        if not t_full:
            continue
        speedup = np.mean(t_full) / max(np.mean(t_inc), 1e-9)
        overhead = 100 * (np.mean(r_inc) / np.mean(r_full) - 1)
        hit = inc.stats["hits"] / max(inc.stats["hits"] + inc.stats["misses"], 1)
        rows.add(f"incremental/{model}", float(np.mean(t_inc)),
                 f"plan_speedup={speedup:.1f}x;resource_overhead_pct={overhead:.1f};"
                 f"shadow_hit_rate={hit:.2f}")

"""Fig. 17: max achievable throughput under a resource cap."""
from __future__ import annotations

import numpy as np

from repro.core import GraftPlanner, plan_gslice, plan_static

from benchmarks.common import Rows, book, timed, PAPER_MODELS
from benchmarks.bench_merging import _frag_population


def _max_load(planner_fn, b, model, cap, step=4, max_n=120):
    """Grow the fragment population until the plan exceeds ``cap`` resource;
    return the highest aggregate RPS that fits."""
    best = 0.0
    for n in range(step, max_n + 1, step):
        frags = _frag_population(model, b, n=n, seed=11)
        plan = planner_fn(frags)
        if not np.isfinite(plan.total_resource) or plan.total_resource > cap:
            break
        best = sum(f.q for f in frags)
    return best


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    cap = 400.0                                            # 4 chips
    models = PAPER_MODELS[:2] if quick else PAPER_MODELS
    for model in models:
        with timed() as tb:
            graft = _max_load(lambda f: GraftPlanner(b).plan(f), b, model,
                              cap, step=8 if quick else 4)
        gslice = _max_load(lambda f: plan_gslice(f, b), b, model, cap,
                           step=8 if quick else 4)
        gslicep = _max_load(lambda f: plan_gslice(f, b, merge_uniform=True),
                            b, model, cap, step=8 if quick else 4)
        ratio = graft / gslice if gslice else float("inf")
        rows.add(f"throughput/fig17/{model}", tb["us"],
                 f"graft_rps={graft:.0f};gslice_rps={gslice:.0f};"
                 f"gslice+_rps={gslicep:.0f};speedup={ratio:.2f}x")

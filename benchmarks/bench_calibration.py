"""Table 2 calibration: the synthesized workload profiles must reproduce the
paper's published per-model latencies (the anchor for every other number)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, book, timed

# paper Table 2: (layers, server_ms @ share 30 batch 1, nano_ms, tx2_ms)
TABLE2 = {
    "inc": (17, 29.0, 165.0, 94.0),
    "res": (16, 30.0, 226.0, 114.0),
    "vgg": (6, 6.0, 147.0, 77.0),
    "mob": (18, 19.0, 84.0, 67.0),
    "vit": (15, 58.0, 816.0, 603.0),
}


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    for model, (L, srv, nano, tx2) in TABLE2.items():
        prof = b[model]
        costs = prof.costs
        with timed() as tb:
            got_srv = float(prof.latency_ms(0, L, 1, 30))
        got_nano = costs.mobile_latency_ms("nano", L)
        got_tx2 = costs.mobile_latency_ms("tx2", L)
        err = max(abs(got_srv - srv) / srv, abs(got_nano - nano) / nano,
                  abs(got_tx2 - tx2) / tx2)
        rows.add(f"calibration/table2/{model}", tb["us"],
                 f"layers={costs.n_layers}/{L};server_ms={got_srv:.1f}/{srv};"
                 f"nano_ms={got_nano:.0f}/{nano:.0f};"
                 f"tx2_ms={got_tx2:.0f}/{tx2:.0f};max_rel_err={err:.3f}")

"""Event-driven GraftServer vs lock-step serve(): makespan + latency.

Both paths deploy the SAME mixed-depth plan (depth-2 aligned clients:
align [0,s) -> shared [s,L); depth-1 clients direct to the shared pool)
over the SAME transport: in-process framing wrapped in a realtime
ShapedTransport, so every client uplink pays its 5G-trace transfer time
and RTT in actual wall clock — serving is network-bound, exactly the
regime the paper budgets for.

  * **lock-step** — ``GraftExecutor.serve`` one wave at a time: every
    shaped uplink sleep and every pool flush happens serially on one
    thread, and depth d+1 cannot start until ALL of depth d flushed.
  * **pipelined** — the server's per-pool driver threads overlap one
    client's uplink transfer with another's stage execution, and
    inter-stage hops ride ONE batched execute frame (a server-internal
    transfer) instead of re-crossing the shaped client-uplink model
    per item the way serve()'s per-item submits do.

Makespan is min-of-rounds (first-shape jit compiles are paid in warm
rounds). The paced phase at realistic budgets yields the bench-gate key
``server_p99_ms`` (non-blocking until a baseline is written).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows


LENS = (8, 12, 16, 24)      # ragged traffic: every wave mixes lengths


def _waves(cfg, frags, rng, n, *, wave0=0):
    """Mixed-length request waves. Length assignment is deterministic in
    (wave, client) so the lock-step and pipelined phases face identical
    ragged traffic — only the execution strategy differs."""
    from repro.serving import ServeRequest
    out = []
    for w in range(n):
        for i, f in enumerate(frags):
            S = LENS[(wave0 + w + i) % len(LENS)]
            out.append((ServeRequest(client=f.client, tokens=rng.randint(
                0, cfg.vocab_size, S).astype(np.int32)), f.p))
    return out


def _shaped(frags):
    from repro.data.traces import synth_5g_trace
    from repro.serving.transport import (InProcessTransport, LinkShape,
                                         ShapedTransport)
    shapes = {f.client: LinkShape(
        trace=synth_5g_trace(seed=100 + i, sigma=0.2, fade_prob=0.0),
        rtt_ms=8.0) for i, f in enumerate(frags)}
    return ShapedTransport(InProcessTransport(), shapes, realtime=True)


def _prewarm(ex, cfg, rng, max_batch):
    """Compile every (pool, length-bucket, batch) shape up front so
    neither path pays a mid-measurement jit trace. Uniform batches of
    each traffic length cover all the padded seq/batch buckets AND all
    the packed token buckets the mixed waves can produce."""
    from repro.serving import ServeRequest
    from repro.serving.batcher import token_bucket
    for key in list(ex.pool_specs()):
        boundary = key[1]
        h = ex.handle(key)
        for S in LENS:
            req = ServeRequest(client="_warm", tokens=rng.randint(
                0, cfg.vocab_size, S).astype(np.int32))
            payload = ex.mobile_part(req, boundary)
            for b in range(1, max_batch + 1):
                h.execute([(ex.next_rid(), "_warm", payload, None)
                           for _ in range(b)])
        if getattr(ex, "packed", False):
            # packed programs key on the TOTAL-token bucket, and the
            # pipelined batcher can close any mix: warm every bucket
            # reachable from this traffic with one exact-length single
            buckets = sorted({token_bucket(t) for t in range(
                min(LENS), max_batch * max(LENS) + 1)})
            for T in buckets:
                req = ServeRequest(client="_warm", tokens=rng.randint(
                    0, cfg.vocab_size, T).astype(np.int32))
                payload = ex.mobile_part(req, boundary)
                h.execute([(ex.next_rid(), "_warm", payload, None)])


def _paced_round(server, cfg, frags, rng, n_paced):
    """One paced round at realistic budgets; returns the round's report."""
    mark = server.mark()
    for _ in range(n_paced):
        for req, p in _waves(cfg, frags, rng, 1):
            server.submit(req, p, budget_ms=80.0)
        time.sleep(0.02)
    server.join(timeout=300.0)
    return server.report(since=mark)


def _pack_stats(ex) -> dict:
    """Aggregate padding/compile counters across an executor's pools."""
    st = ex.pool_stats().values()
    real = sum(s["real_tokens"] for s in st)
    pad = sum(s["pad_tokens"] for s in st)
    comp = sum(s["n_compiles"] for s in st)
    return {"real": real, "pad": pad, "compiles": comp,
            "waste": pad / max(real + pad, 1)}


def run(rows: Rows, *, quick=False) -> None:
    from repro.core import Fragment
    from repro.serving import GraftExecutor, GraftServer
    from repro.serving.smoke import mixed_depth_plan, smoke_setup
    from repro.serving.telemetry import Telemetry

    # 4-block reduced model so the aligned topology has real depth:
    # p=0 clients run align [0,1) -> shared [1,4); p=1 clients go direct
    cfg, book, params = smoke_setup("qwen3-1.7b", seed=0, n_layers=4)
    frags = [Fragment(cfg.name, 0, 80.0, 30.0, client="a0"),
             Fragment(cfg.name, 1, 60.0, 30.0, client="b1"),
             Fragment(cfg.name, 1, 70.0, 30.0, client="b2"),
             Fragment(cfg.name, 0, 90.0, 30.0, client="b3")]
    if quick:
        frags = frags[:3]
    waves = 3 if quick else 6
    rounds = 3 if quick else 5
    plan = mixed_depth_plan(cfg, book, frags, s=1, batch=4)
    rng = np.random.RandomState(0)

    # ---- lock-step baseline: serve() one wave at a time, pad-to-bucket --
    # packed=False: the per-request padding baseline the packed path is
    # gated against (padding_waste_frac / recompile_count).
    lock_times = []
    with GraftExecutor(plan, params, cfg, transport=_shaped(frags),
                       packed=False) as ex:
        _prewarm(ex, cfg, rng, max_batch=len(frags))
        for _ in range(2):                      # warm the serve() path too
            ex.serve(_waves(cfg, frags, rng, 1))
        for _ in range(rounds):
            reqs = _waves(cfg, frags, rng, waves)
            per_wave = len(frags)
            t0 = time.perf_counter()
            for w in range(waves):
                ex.serve(reqs[w * per_wave:(w + 1) * per_wave])
            lock_times.append(time.perf_counter() - t0)
        padded_stats = _pack_stats(ex)

    # ---- pipelined: every wave in flight across pool drivers, packed ----
    pipe_times = []
    ex2 = GraftExecutor(plan, params, cfg, transport=_shaped(frags),
                        packed=True)
    _prewarm(ex2, cfg, rng, max_batch=len(frags))
    server = GraftServer(ex2, book=book).start()
    server_on = None                  # telemetry-enabled twin, started later
    try:
        for req, p in _waves(cfg, frags, rng, 2):          # warm the path
            server.submit(req, p, budget_ms=0.0)
        server.join(timeout=300.0)
        for _ in range(rounds):
            reqs = _waves(cfg, frags, rng, waves)
            t0 = time.perf_counter()
            for req, p in reqs:
                # zero budget => flush deadlines are NOW: throughput mode
                server.submit(req, p, budget_ms=0.0)
            if not server.join(timeout=300.0):
                raise RuntimeError("pipelined round never drained")
            pipe_times.append(time.perf_counter() - t0)

        lock_ms = min(lock_times) * 1e3
        pipe_ms = min(pipe_times) * 1e3
        ratio = lock_ms / max(pipe_ms, 1e-9)
        n_req = waves * len(frags)
        rows.add("server/makespan/lockstep", lock_ms * 1e3,
                 f"ms={lock_ms:.2f};waves={waves};requests={n_req}")
        rows.add("server/makespan/pipelined", pipe_ms * 1e3,
                 f"ms={pipe_ms:.2f};ratio={ratio:.2f};"
                 f"mean_batch={server.report()['mean_batch']:.2f}")

        # ---- the cost of observability. "Cheap enough to leave on" is a
        # gated claim, not a hope: a SECOND server over the same warm
        # executor runs with a live registry and every request span-
        # sampled, and its throughput-mode makespan is compared against
        # the plain server's. Budget-0 makespan is the right meter:
        # paced-mode latency at realistic budgets is dominated by
        # deadline-alignment luck (±30% round-to-round — far above any
        # 5% gate), while min-of-interleaved-rounds makespan converges
        # on the true floor, where a constant per-request cost shows
        # directly. Off/on rounds alternate order so machine-load drift
        # hits both variants equally.
        tel = Telemetry(process="bench", trace=True)
        server_on = GraftServer(ex2, book=book, telemetry=tel).start()
        for req, p in _waves(cfg, frags, rng, 2):      # warm its drivers
            server_on.submit(req, p, budget_ms=0.0)
        server_on.join(timeout=300.0)
        # makespan rounds are ~0.1 s each — take plenty: the min of many
        # interleaved rounds pins each variant's floor to well under the
        # 5% ceiling's resolution, where a min-of-few still wobbles ±10%
        off_times, on_times = [], []
        for i in range(12 if quick else 20):
            pair = [(server, off_times), (server_on, on_times)]
            if i % 2:                 # alternate order: balanced vs drift
                pair.reverse()
            for srv, acc in pair:
                reqs = _waves(cfg, frags, rng, waves)
                t0 = time.perf_counter()
                for req, p in reqs:
                    srv.submit(req, p, budget_ms=0.0)
                if not srv.join(timeout=300.0):
                    raise RuntimeError("telemetry round never drained")
                acc.append(time.perf_counter() - t0)
        off_ms = min(off_times) * 1e3
        on_ms = min(on_times) * 1e3
        overhead = max(on_ms - off_ms, 0.0) / max(off_ms, 1e-9)
        rows.add("server/telemetry", on_ms * 1e3,
                 f"telemetry_overhead_frac={overhead:.4f};"
                 f"makespan_off_ms={off_ms:.3f};makespan_on_ms={on_ms:.3f};"
                 f"spans={len(tel.spans)}")

        # ---- paced phase at realistic budgets: latency/p99 ------------
        # Best-of-rounds: single-round tails on a small shared box are
        # dominated by scheduler noise.
        n_paced = 10 if quick else 30
        rep = None
        for _ in range(3):
            rep_i = _paced_round(server, cfg, frags, rng, n_paced)
            if rep is None or rep_i["p99_ms"] < rep["p99_ms"]:
                rep = rep_i
        rows.add("server/latency", rep["p99_ms"] * 1e3,
                 f"p50_ms={rep['p50_ms']:.2f};p99_ms={rep['p99_ms']:.2f};"
                 f"attainment={rep['attainment']:.3f};"
                 f"mean_batch={rep['mean_batch']:.2f};n={rep['served']}")

        # ---- packing efficiency: ragged vs pad-to-bucket ----------------
        # Same mixed-length traffic through both executors; the packed
        # row carries the gated keys. Counters are whole-run (prewarm
        # included): recompile_count IS the count of distinct shapes the
        # pool programs ever traced.
        packed_stats = _pack_stats(ex2)
        for name, st in (("padded", padded_stats), ("packed", packed_stats)):
            rows.add(f"server/packing/{name}", st["waste"] * 1e6,
                     f"padding_waste_frac={st['waste']:.4f};"
                     f"recompile_count={st['compiles']};"
                     f"real_tokens={st['real']};pad_tokens={st['pad']}")
    finally:
        if server_on is not None:
            server_on.stop(drain=False, timeout=5.0)
        server.stop(drain=False, timeout=5.0)
        ex2.close()

"""Fig. 18: massive-scale simulation (hundreds-thousands of fragments),
merging threshold 0.01 per §5.8."""
from __future__ import annotations

import numpy as np

from repro.core import GraftPlanner, plan_gslice

from benchmarks.common import Rows, book, timed, PAPER_MODELS
from benchmarks.bench_merging import _frag_population


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    n = 200 if quick else 1000
    for model in (PAPER_MODELS[:2] if quick else PAPER_MODELS):
        frags = _frag_population(model, b, n=n, seed=13)
        with timed() as tb:
            g = GraftPlanner(b, merging_threshold=0.01).plan(frags)
        gs = plan_gslice(frags, b)
        gsp = plan_gslice(frags, b, merge_uniform=True)
        rows.add(f"massive/fig18/{model}/n{n}", tb["us"],
                 f"graft={g.total_resource:.0f};gslice={gs.total_resource:.0f};"
                 f"gslice+={gsp.total_resource:.0f};"
                 f"gslice_over_graft={gs.total_resource/max(g.total_resource,1e-9):.2f}x;"
                 f"n_merged={g.n_fragments_merged}")

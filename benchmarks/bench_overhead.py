"""Fig. 19 / §5.9: scheduler time & memory vs Optimal's enumeration."""
from __future__ import annotations

import tracemalloc

import numpy as np

from repro.core import GraftPlanner, plan_optimal

from benchmarks.common import Rows, book, timed, PAPER_MODELS
from benchmarks.bench_merging import _frag_population


def run(rows: Rows, *, quick=False) -> None:
    b = book()
    counts = [10, 25] if quick else [10, 25, 50]
    for model in (PAPER_MODELS[:2] if quick else PAPER_MODELS):
        for n in counts:
            frags = _frag_population(model, b, n=n, seed=17)
            tracemalloc.start()
            with timed() as tb:
                GraftPlanner(b).plan(frags)
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            rows.add(f"overhead/fig19/{model}/n{n}", tb["us"],
                     f"time_ms={tb['us']/1e3:.1f};peak_mem_mb={peak/2**20:.1f}")
        # Optimal at n=8 (its enumeration explodes beyond ~10)
        frags = _frag_population(model, b, n=8, seed=17)
        with timed() as tg:
            GraftPlanner(b, merge_strategy="none").plan(frags)
        with timed() as to:
            plan_optimal(frags, b)
        red = 100 * (1 - tg["us"] / to["us"]) if to["us"] else 0.0
        rows.add(f"overhead/vs_optimal/{model}/n8", tg["us"],
                 f"graft_ms={tg['us']/1e3:.1f};optimal_ms={to['us']/1e3:.1f};"
                 f"time_reduction_pct={red:.1f}")
